"""Unit + property tests for the RBLA core (the paper's Eq. 6-7, Alg. 1).

Includes the paper's Section-3 toy example (Eq. 2-3): with zero-padding the
last row of the aggregate is diluted by w1/(w1+w2); with RBLA it is
preserved verbatim from the only client that owns it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (aggregate, fedavg_leaf, rank_mask, axis_mask,
                        pad_to_rank, rbla_leaf, slice_to_rank,
                        stacked_rank_masks, zeropad_leaf,
                        rank_proportional_weights, rbla_norm_leaf,
                        svd_project_pair)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- masks ----
def test_rank_mask_basic():
    np.testing.assert_array_equal(np.asarray(rank_mask(5, 3)),
                                  [1, 1, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(rank_mask(4, 4)), [1, 1, 1, 1])
    np.testing.assert_array_equal(np.asarray(rank_mask(4, 0)), [0, 0, 0, 0])


def test_axis_mask_rows_and_cols():
    m0 = np.asarray(axis_mask((4, 3), axis=0, rank=2))
    assert m0.sum() == 2 * 3 and m0[:2].all() and not m0[2:].any()
    m1 = np.asarray(axis_mask((4, 3), axis=-1, rank=1))
    assert m1.sum() == 4 and m1[:, 0].all() and not m1[:, 1:].any()


def test_stacked_rank_masks():
    m = np.asarray(stacked_rank_masks(4, jnp.array([1, 4, 0])))
    np.testing.assert_array_equal(m, [[1, 0, 0, 0], [1, 1, 1, 1],
                                      [0, 0, 0, 0]])


def test_pad_slice_roundtrip():
    x = jnp.arange(6.0).reshape(2, 3)
    p = pad_to_rank(x, axis=0, r_max=5)
    assert p.shape == (5, 3) and np.asarray(p[2:]).sum() == 0
    np.testing.assert_array_equal(np.asarray(slice_to_rank(p, 0, 2)),
                                  np.asarray(x))


# ------------------------------------------------- paper's toy example ----
def test_paper_eq3_toy_example():
    """Paper Eq. 2-3: A (2x3) zero-padded to 3x3, aggregated with B (3x3)."""
    A = jnp.array([[1., 2., 3.], [4., 5., 6.]])
    B = jnp.array([[10., 10., 10.], [10., 10., 10.], [8., 8., 8.]])
    w = jnp.array([1.0, 1.0])
    stacked = jnp.stack([pad_to_rank(A, 0, 3), B])
    masks = jnp.stack([axis_mask((3, 3), 0, 2), axis_mask((3, 3), 0, 3)])

    zp = np.asarray(zeropad_leaf(stacked, masks, w))
    # dilution: last row halves (Eq. 3)
    np.testing.assert_allclose(zp[2], [4., 4., 4.])

    rb = np.asarray(rbla_leaf(stacked, masks, w))
    # RBLA: last row preserved from the only contributor (Eq. 7)
    np.testing.assert_allclose(rb[2], [8., 8., 8.])
    # shared rows identical between the two methods
    np.testing.assert_allclose(rb[:2], zp[:2])


def test_rbla_row_absent_everywhere_is_zero():
    stacked = jnp.ones((3, 4, 2))
    masks = stacked_rank_masks(4, jnp.array([2, 2, 1]))[:, :, None]
    out = np.asarray(rbla_leaf(stacked, masks, jnp.ones(3)))
    assert (out[2:] == 0).all() and (out[:2] == 1).all()


# -------------------------------------------------------- equivalences ----
def test_rbla_equals_fedavg_when_homogeneous():
    rng = np.random.default_rng(0)
    stacked = jnp.asarray(rng.normal(size=(5, 8, 6)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=5), jnp.float32)
    full = stacked_rank_masks(8, jnp.full((5,), 8))[:, :, None]
    np.testing.assert_allclose(np.asarray(rbla_leaf(stacked, full, w)),
                               np.asarray(fedavg_leaf(stacked, w)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(zeropad_leaf(stacked, full, w)),
                               np.asarray(fedavg_leaf(stacked, w)),
                               rtol=1e-5, atol=1e-6)


def test_aggregate_pytree_dispatch():
    tree = {"A": jnp.ones((2, 4, 3)), "bias": jnp.ones((2, 3))}
    masks = {"A": stacked_rank_masks(4, jnp.array([2, 4]))[:, :, None],
             "bias": jnp.ones(())}  # 0-d => fully shared
    w = jnp.ones(2)
    out = aggregate(tree, masks, w, method="rbla")
    assert out["A"].shape == (4, 3) and out["bias"].shape == (3,)
    np.testing.assert_allclose(np.asarray(out["A"]), 1.0)
    with pytest.raises(ValueError):
        aggregate(tree, masks, w, method="nope")


# ----------------------------------------------------- property tests  ----
leaf_shapes = st.tuples(st.integers(2, 6), st.integers(1, 8),
                        st.integers(1, 5))


@settings(max_examples=30, deadline=None)
@given(shape=leaf_shapes, seed=st.integers(0, 2 ** 16))
def test_prop_rbla_convex_per_row(shape, seed):
    """Each output element lies in the convex hull of contributing clients'
    values (masked weighted mean) -- never diluted toward 0 by absentees."""
    n, r, d = shape
    rng = np.random.default_rng(seed)
    stacked = rng.normal(size=(n, r, d)).astype(np.float32)
    ranks = rng.integers(1, r + 1, size=n)
    w = rng.uniform(0.1, 3.0, size=n).astype(np.float32)
    masks = np.asarray(stacked_rank_masks(r, jnp.asarray(ranks)))[:, :, None]
    out = np.asarray(rbla_leaf(jnp.asarray(stacked * masks),
                               jnp.asarray(masks), jnp.asarray(w)))
    for row in range(r):
        contrib = [stacked[i, row] for i in range(n) if ranks[i] > row]
        if not contrib:
            np.testing.assert_allclose(out[row], 0.0, atol=1e-6)
            continue
        lo = np.min(contrib, axis=0) - 1e-4
        hi = np.max(contrib, axis=0) + 1e-4
        assert (out[row] >= lo).all() and (out[row] <= hi).all()


@settings(max_examples=30, deadline=None)
@given(shape=leaf_shapes, seed=st.integers(0, 2 ** 16))
def test_prop_zeropad_dilutes_rbla_does_not(shape, seed):
    """|ZP row| <= |RBLA row| elementwise on rows not owned by everyone
    (with equal client weights and same-sign contributions)."""
    n, r, d = shape
    rng = np.random.default_rng(seed)
    stacked = np.abs(rng.normal(size=(n, r, d))).astype(np.float32) + 0.1
    ranks = rng.integers(1, r + 1, size=n)
    masks = np.asarray(stacked_rank_masks(r, jnp.asarray(ranks)))[:, :, None]
    w = jnp.ones(n)
    zp = np.asarray(zeropad_leaf(jnp.asarray(stacked * masks),
                                 jnp.asarray(masks), w))
    rb = np.asarray(rbla_leaf(jnp.asarray(stacked * masks),
                              jnp.asarray(masks), w))
    assert (zp <= rb + 1e-5).all()
    # and they agree exactly on rows owned by every client
    for row in range(r):
        if (ranks > row).all():
            np.testing.assert_allclose(zp[row], rb[row], rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_prop_rbla_idempotent_on_identical_clients(seed):
    """Aggregating N copies of the same adapter returns it unchanged."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(6, 4)).astype(np.float32)
    stacked = jnp.asarray(np.stack([x] * 4))
    masks = stacked_rank_masks(6, jnp.full((4,), 6))[:, :, None]
    out = np.asarray(rbla_leaf(stacked, masks,
                               jnp.asarray(rng.uniform(0.5, 2, 4),
                                           jnp.float32)))
    np.testing.assert_allclose(out, x, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- variants ----
def test_rank_proportional_weights_preserve_mass():
    w = jnp.array([1., 1., 2.])
    r = jnp.array([2, 4, 8])
    out = rank_proportional_weights(w, r)
    np.testing.assert_allclose(float(jnp.sum(out)), 4.0, rtol=1e-5)
    assert float(out[2]) > float(out[1]) > float(out[0])


def test_rbla_norm_restores_magnitude():
    # two orthogonal unit rows average to norm 1/sqrt(2); variant restores ~1
    a = np.zeros((2, 1, 4), np.float32)
    a[0, 0, 0] = 1.0
    a[1, 0, 1] = 1.0
    stacked = jnp.asarray(a)
    masks = jnp.ones((2, 1, 1))
    plain = np.linalg.norm(np.asarray(rbla_leaf(stacked, masks, jnp.ones(2))))
    fixed = np.linalg.norm(np.asarray(
        rbla_norm_leaf(stacked, masks, jnp.ones(2), row_axis=0)))
    assert abs(plain - 1 / np.sqrt(2)) < 1e-5
    assert abs(fixed - 1.0) < 1e-4


def test_svd_project_exact_for_single_client():
    rng = np.random.default_rng(3)
    B = rng.normal(size=(1, 8, 3)).astype(np.float32)
    A = rng.normal(size=(1, 3, 6)).astype(np.float32)
    Bo, Ao = svd_project_pair(jnp.asarray(B), jnp.asarray(A),
                              jnp.array([3]), jnp.ones(1), r_out=3)
    np.testing.assert_allclose(np.asarray(Bo) @ np.asarray(Ao),
                               B[0] @ A[0], rtol=1e-4, atol=1e-4)


def test_rbla_prev_retention_partial_participation():
    """Under partial participation, rank-rows owned by NO participant must
    retain the server's previous value (not be zeroed) -- the regression
    behind the random-20% collapse found in SSRepro claim 3."""
    prev = jnp.full((4, 3), 7.0)
    # two low-rank participants (ranks 1 and 2): rows 2..3 unowned
    stacked = jnp.ones((2, 4, 3))
    masks = stacked_rank_masks(4, jnp.array([1, 2]))[:, :, None]
    out = np.asarray(rbla_leaf(stacked * masks, masks, jnp.ones(2),
                               prev=prev))
    np.testing.assert_allclose(out[0], 1.0)      # owned by both
    np.testing.assert_allclose(out[1], 1.0)      # owned by client 2
    np.testing.assert_allclose(out[2], 7.0)      # unowned -> retained
    np.testing.assert_allclose(out[3], 7.0)
    # without prev: unowned rows are zero (full-participation semantics)
    out0 = np.asarray(rbla_leaf(stacked * masks, masks, jnp.ones(2)))
    np.testing.assert_allclose(out0[2:], 0.0)


def test_aggregate_threads_prev_tree():
    tree = {"A": jnp.ones((2, 4, 3))}
    masks = {"A": stacked_rank_masks(4, jnp.array([1, 1]))[:, :, None]}
    prev = {"A": jnp.full((4, 3), 5.0)}
    out = aggregate(jax.tree.map(lambda x, m: x * m, tree, masks), masks,
                    jnp.ones(2), method="rbla", prev_tree=prev)
    np.testing.assert_allclose(np.asarray(out["A"][0]), 1.0)
    np.testing.assert_allclose(np.asarray(out["A"][1:]), 5.0)
