"""Property suite for the upload codecs (``repro.core.codec``).

Runs under ``tests/_hypothesis_stub.py`` (containers without hypothesis)
and under real hypothesis (the CI matrix leg installs it); only the
stub's API subset is used: ``given`` with keyword strategies,
``settings``, and ``strategies.integers / tuples / sampled_from``.

Properties:

* **int8 round-trip bound**: per coordinate,
  ``|x - decode(encode(x))| <= scale/2`` with ``scale = max|row|/127``
  on the packed-row convention (A rows, B columns), and the encoder's
  published scales equal that bound's scales exactly;
* **bf16 exactness**: values already representable in bf16 survive the
  bf16 codec bit-for-bit, and the int8 codec is exact on rows whose
  values are integer multiples of their scale;
* **codec composition**: for every registered strategy (every
  ``plan_mode``: mean, mean_norm, robust combine, svd, stack), the
  aggregate of an encoded cohort equals the aggregate of the *decoded*
  cohort (the fused-dequant plan vs the eager-decode oracle), and the
  ``none`` codec is bit-exact against the raw fp32 cohort;
* **robust breakdown point**: the trimmed / median / clipped strategies
  still bound an adversarial client's pull when every upload (attacker
  included) ships int8 -- quantization must not widen the breakdown
  bounds the robust suite already guarantees.

Stochastic rounding (the server-side half of quantized transport) is
covered here too: determinism under a fixed key, fixed points on
bf16-representable inputs, and an unbiasedness CLT bound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _cohorts import (R_MAX, assert_trees_close, hetero_cohort,
                      mixed_codec_cohort)
from repro.core import codec
from repro.core.strategy import get_strategy, list_strategies

jax.config.update("jax_platform_name", "cpu")

ALL_METHODS = tuple(sorted(list_strategies()))
ROBUST_METHODS = ("rbla_clipped", "rbla_trimmed", "rbla_median")
#: quantized-vs-fp32 agreement is bounded by the codec's per-row error;
#: encoded-vs-decoded agreement is a numerics identity and uses the
#: suite-wide tight tolerance instead
INT8_COHORT_ATOL = 0.05


def configured(method):
    s = get_strategy(method)
    if s.rank_contract == "stacked":
        s = s.with_options(stack_r_cap=8 * R_MAX)
    return s


# ------------------------------------------------------------ round-trip --
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), r=st.integers(1, R_MAX))
def test_int8_round_trip_bounded_by_row_scale(seed, r):
    rng = np.random.default_rng(seed)
    pair = {"A": jnp.asarray(rng.normal(size=(r, 7)) * 3.0, jnp.float32),
            "B": jnp.asarray(rng.normal(size=(9, r)) * 0.1, jnp.float32),
            "rank": jnp.asarray(r, jnp.int32)}
    enc = codec.encode_pair(pair, "int8")
    dec = codec.decode_pair(enc)
    # published scales match the symmetric per-row definition exactly
    np.testing.assert_allclose(
        np.asarray(enc["A_scale"]),
        np.maximum(np.abs(np.asarray(pair["A"])).max(axis=-1), 0) / 127.0
        + (np.abs(np.asarray(pair["A"])).max(axis=-1) == 0) * 1.0)
    # |x - dec| <= scale/2 per coordinate, rows resp. columns
    err_a = np.abs(np.asarray(pair["A"]) - np.asarray(dec["A"]))
    assert np.all(err_a <= 0.5 * np.asarray(enc["A_scale"])[:, None] + 1e-7)
    err_b = np.abs(np.asarray(pair["B"]) - np.asarray(dec["B"]))
    assert np.all(err_b <= 0.5 * np.asarray(enc["B_scale"])[None, :] + 1e-7)
    assert enc["A"].dtype == jnp.int8 and enc["B"].dtype == jnp.int8
    # rank metadata is never quantized
    assert int(dec["rank"]) == r


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_codec_exactness_on_representable_values(seed):
    rng = np.random.default_rng(seed)
    # bf16-representable: f32 rounded through bf16 once is a fixed point
    x = jnp.asarray(rng.normal(size=(4, 6)), jnp.bfloat16).astype(
        jnp.float32)
    pair = {"A": x, "B": x.T, "rank": jnp.asarray(4, jnp.int32)}
    dec = codec.decode_pair(codec.encode_pair(pair, "bf16"))
    assert np.array_equal(np.asarray(dec["A"]), np.asarray(x))
    # int8-exact rows: integer multiples of scale = amax/127
    q = rng.integers(-127, 128, size=(4, 6)).astype(np.float32)
    q[:, 0] = 127.0     # pin every row's amax so every scale = 1/8
    xa = jnp.asarray(q / 8.0, jnp.float32)
    pair = {"A": xa, "B": xa.T, "rank": jnp.asarray(4, jnp.int32)}
    dec = codec.decode_pair(codec.encode_pair(pair, "int8"))
    np.testing.assert_allclose(np.asarray(dec["A"]), np.asarray(xa),
                               rtol=0, atol=1e-6)


def test_decode_idempotent_and_none_passthrough():
    adapters, _, _ = hetero_cohort(n=1, seed=5)
    assert codec.encode_adapters(adapters[0], "none") is adapters[0]
    once = codec.decode_adapters(adapters[0])
    assert_trees_close(once, codec.decode_adapters(once), rtol=0, atol=0)
    assert codec.tree_codec(adapters[0]) == "none"
    assert codec.cohort_codecs(adapters) is None


# ----------------------------------------------------------- composition --
@settings(max_examples=10, deadline=None)
@given(method=st.sampled_from(ALL_METHODS),
       seed=st.integers(0, 1_000),
       wire=st.sampled_from(("int8", "bf16", "uniform_mix")))
def test_codec_composes_with_every_strategy(method, seed, wire):
    """Encoded aggregate == decoded-cohort aggregate for every registered
    ``plan_mode`` (fused-dequant plan where one exists, eager decode
    elsewhere), and within codec tolerance of the raw fp32 aggregate."""
    n = 5
    names = ([wire] * n if wire != "uniform_mix"
             else [("int8", "bf16", "none")[i % 3] for i in range(n)])
    enc, dec, ranks, weights, _ = mixed_codec_cohort(n=n, seed=seed,
                                                     codecs=names)
    _, plain, _, _, _ = mixed_codec_cohort(n=n, seed=seed, codecs=["none"] * n)
    for backend in ("ref", "pallas"):
        s_enc, s_dec, s_raw = (configured(method) for _ in range(3))
        try:
            got = s_enc.aggregate_adapters(enc, weights, r_max=R_MAX,
                                           client_ranks=ranks,
                                           backend=backend)
        except NotImplementedError:
            continue                    # backend unsupported: documented
        oracle = s_dec.aggregate_adapters(dec, weights, r_max=R_MAX,
                                          client_ranks=ranks,
                                          backend=backend)
        assert_trees_close(oracle, got, rtol=1e-4, atol=1e-5,
                           msg=f"{method}/{backend}/{wire} enc-vs-dec")
        raw = s_raw.aggregate_adapters(plain, weights, r_max=R_MAX,
                                       client_ranks=ranks, backend=backend)
        assert_trees_close(raw, got, rtol=0.1, atol=INT8_COHORT_ATOL,
                           msg=f"{method}/{backend}/{wire} quant drift")


@settings(max_examples=8, deadline=None)
@given(method=st.sampled_from(ALL_METHODS), seed=st.integers(0, 1_000))
def test_none_codec_is_bit_exact(method, seed):
    adapters, ranks, weights = hetero_cohort(n=4, seed=seed)
    s_a, s_b = configured(method), configured(method)
    base = s_a.aggregate_adapters(adapters, weights, r_max=R_MAX,
                                  client_ranks=ranks, backend="ref")
    enc = [codec.encode_adapters(a, "none") for a in adapters]
    got = s_b.aggregate_adapters(enc, weights, r_max=R_MAX,
                                 client_ranks=ranks, backend="ref")
    for x, y in zip(jax.tree.leaves(base), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), method


# ------------------------------------------------------- breakdown point --
@pytest.mark.parametrize("method", ROBUST_METHODS)
def test_robust_breakdown_survives_int8_uploads(method):
    """One adversarial client blowing its update up to ~1e6 must stay
    bounded when the whole cohort (attacker included) ships int8 -- the
    plan dequantizes before any clip or order statistic, so quantization
    cannot widen the robust bounds."""
    adapters, _, _ = hetero_cohort(n=5, seed=41, r_lo=R_MAX, r_hi=R_MAX)
    ranks = jnp.full((5,), R_MAX, jnp.int32)
    weights = jnp.ones((5,), jnp.float32)
    evil = [jax.tree.map(
        lambda x: x * 1e6 if x.dtype == jnp.float32 else x, adapters[0])
        ] + list(adapters[1:])
    s = get_strategy(method)
    if method == "rbla_clipped":
        s = s.with_options(clip_norm=5.0)
    if method == "rbla_trimmed":
        s = s.with_options(trim_frac=0.3)
    clean = s.aggregate_adapters(adapters, weights, r_max=R_MAX,
                                 client_ranks=ranks, backend="ref")
    enc = [codec.encode_adapters(a, "int8") for a in evil]
    s2 = get_strategy(method)
    if method == "rbla_clipped":
        s2 = s2.with_options(clip_norm=5.0)
    if method == "rbla_trimmed":
        s2 = s2.with_options(trim_frac=0.3)
    attacked = s2.aggregate_adapters(enc, weights, r_max=R_MAX,
                                     client_ranks=ranks, backend="ref")
    move = max(float(jnp.max(jnp.abs(
        jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))))
        for x, y in zip(jax.tree.leaves(clean), jax.tree.leaves(attacked)))
    assert move < 50.0, f"{method}: robust bound broken under int8 ({move})"
    # the unprotected mean, for contrast, is dragged far away
    mean_attacked = get_strategy("rbla").aggregate_adapters(
        enc, weights, r_max=R_MAX, client_ranks=ranks, backend="ref")
    mean_move = max(float(jnp.max(jnp.abs(
        jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))))
        for x, y in zip(jax.tree.leaves(clean),
                        jax.tree.leaves(mean_attacked)))
    assert mean_move > 1e4


# ------------------------------------------------------------ validation --
def test_validate_rejects_bad_scales():
    adapters, _, _ = hetero_cohort(n=1, seed=7)
    enc = codec.encode_adapters(adapters[0], "int8")
    codec.validate_encoded_adapters(enc)            # well-formed: clean
    codec.validate_encoded_adapters(adapters[0])    # plain fp32: no-op
    for poison in (jnp.nan, jnp.inf, 0.0, -1.0):
        bad = {k: dict(v) for k, v in enc.items()}
        bad["fc1"]["A_scale"] = bad["fc1"]["A_scale"].at[0].set(poison)
        with pytest.raises(ValueError, match="scale"):
            codec.validate_encoded_adapters(bad)
    big = {k: dict(v) for k, v in enc.items()}
    big["fc2"]["B_scale"] = big["fc2"]["B_scale"].at[0].set(3.0e36)
    with pytest.raises(ValueError, match="overflow"):
        codec.validate_encoded_adapters(big)


def test_unknown_codec_rejected():
    adapters, _, _ = hetero_cohort(n=1, seed=7)
    with pytest.raises(ValueError, match="unknown codec"):
        codec.encode_adapters(adapters[0], "fp4")


# ---------------------------------------------------- stochastic rounding --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_stochastic_round_deterministic_and_fixed_points(seed):
    key = jax.random.PRNGKey(seed)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(32, 17)),
                    jnp.float32)
    a = codec.stochastic_round(x, key)
    b = codec.stochastic_round(x, key)
    assert np.array_equal(np.asarray(a, np.float32),
                          np.asarray(b, np.float32))
    assert a.dtype == jnp.bfloat16
    # bf16-representable values never move, whatever the noise
    xr = x.astype(jnp.bfloat16).astype(jnp.float32)
    r = codec.stochastic_round(xr, jax.random.PRNGKey(seed + 1))
    assert np.array_equal(np.asarray(r, np.float32), np.asarray(xr))
    # one ulp is the hard worst case for a single rounding
    ulp = np.abs(np.asarray(xr)) * 2.0 ** -7 + 2.0 ** -126
    assert np.all(np.abs(np.asarray(a, np.float32) - np.asarray(x))
                  <= ulp + np.abs(np.asarray(x)) * 2.0 ** -8)


def test_stochastic_round_unbiased():
    """E[SR(x)] == x: the mean of many independently-keyed roundings
    converges at the CLT rate, far inside one deterministic-rounding
    ulp."""
    x = jnp.full((256,), 1.0 + 2.0 ** -9, jnp.float32)   # mid-interval
    n = 400
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    acc = np.zeros(x.shape, np.float64)
    for k in keys:
        acc += np.asarray(codec.stochastic_round(x, k), np.float32)
    mean_err = abs(acc.mean() / n - float(x[0]))
    # one bf16 ulp at 1.0 is 2^-8; the CLT bound over n*256 samples is
    # ~ulp / sqrt(n*256) ~ 1.2e-5; allow 5 sigma
    assert mean_err < 5 * (2.0 ** -8) / np.sqrt(n * 256), mean_err
    # deterministic rounding of the same value is off by ~2^-9: SR wins
    det_err = abs(float(x.astype(jnp.bfloat16).astype(jnp.float32)[0])
                  - float(x[0]))
    assert mean_err < det_err / 10


def test_stochastic_round_tree_and_edge_cases():
    tree = {"w": jnp.ones((3, 3), jnp.float32) * 1.25,
            "rank": jnp.asarray(3, jnp.int32)}
    out = codec.stochastic_round_tree(tree, jax.random.PRNGKey(2))
    assert out["w"].dtype == jnp.bfloat16
    assert out["rank"].dtype == jnp.int32          # int leaves untouched
    # non-finite passthrough (ingestion rejects them; SR must not mangle)
    x = jnp.asarray([jnp.nan, jnp.inf, -jnp.inf, 0.0], jnp.float32)
    r = np.asarray(codec.stochastic_round(x, jax.random.PRNGKey(3)),
                   np.float32)
    assert np.isnan(r[0]) and np.isposinf(r[1]) and np.isneginf(r[2])
    assert r[3] == 0.0
    with pytest.raises(ValueError, match="bfloat16"):
        codec.stochastic_round(x, jax.random.PRNGKey(4), jnp.float16)
