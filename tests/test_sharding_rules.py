"""Sharding rules unit tests (no multi-device needed: specs are data)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import make_model
from repro.sharding import rules


@pytest.fixture(scope="module")
def mesh():
    # a FAKE mesh object is enough for spec computation: rules only use
    # axis names/sizes
    dev = np.asarray(jax.devices() * 1)[:1].reshape(1, 1)
    m = Mesh(dev, ("data", "model"))
    return m


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


class FakeMesh1:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_maybe_divisibility():
    m = FakeMesh1()
    assert rules.maybe(m, 64, "model") == "model"
    assert rules.maybe(m, 50280, "model") is None   # mamba vocab: uneven
    assert rules.axis_size(FakeMesh(), ("pod", "data")) == 32


def test_param_specs_yi():
    cfg = get_config("yi-34b")
    model = make_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = rules.param_specs(shapes, FakeMesh1())
    # embedding sharded on vocab (64000 % 16 == 0)
    assert specs["embed"]["table"] == P("model", None)
    st = specs["stages"][0]["b0"]
    # fused q (L, d, H*hd): column-parallel on fan-out
    assert st["mix"]["q"]["w"] == P(None, None, "model")
    # o: row-parallel on fan-in
    assert st["mix"]["o"]["w"] == P(None, "model", None)
    assert st["ffn"]["down"]["w"] == P(None, "model", None)
    # norms replicated
    assert st["mix"]["ln"]["scale"] == P(None, None)


def test_param_specs_moe_expert_axis():
    cfg = get_config("deepseek-v3-671b")
    model = make_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = rules.param_specs(shapes, FakeMesh1())
    moe = specs["stages"][1]["b0"]["ffn"]
    # experts (L, E, d, f): E sharded over model (256 % 16 == 0)
    assert moe["experts"]["gate"]["w"] == P(None, "model", None, None)
    # router replicated
    assert moe["router"]["w"] == P(None, None, None)


def test_param_specs_fsdp_shards_contracting_dim():
    cfg = get_config("yi-34b")
    model = make_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = rules.param_specs(shapes, FakeMesh1(), fsdp=True)
    st = specs["stages"][0]["b0"]
    assert st["mix"]["q"]["w"] == P(None, ("data",), "model")
    assert st["ffn"]["down"]["w"] == P(None, "model", ("data",))


def test_batch_and_cache_specs():
    m = FakeMesh()
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = rules.batch_specs(batch, m)
    assert specs["tokens"] == P(("pod", "data"), None)
    # batch=1 long-context: shard cache time axis instead
    cfg = get_config("gemma2-9b")
    model = make_model(cfg)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(1, 524288))
    cspecs = rules.cache_specs(cache_shapes, m, global_batch=1)
    # global layer (b1) kv cache: (L, B, T, KV, hd) -> T sharded
    leaf = cspecs[0]["b1"]["k"]
    assert leaf[2] == ("pod", "data")
    # windowed layer (b0): T=4096 also divisible -> sharded is fine too
    dec = rules.cache_specs(cache_shapes, m, global_batch=128)
    assert dec[0]["b1"]["k"][1] == ("pod", "data")


def test_adapter_specs_expert_axis():
    cfg = get_config("deepseek-v3-671b")
    model = make_model(cfg)
    shapes = jax.eval_shape(
        lambda k: model.init_adapters(k, rank=8), jax.random.PRNGKey(0))
    specs = rules.adapter_specs(shapes, FakeMesh1())
    pair = specs["stages"][1]["b0"]["ffn/experts/gate"]
    assert pair["A"] == P(None, "model", None, None)
    # non-expert adapters replicated
    q = specs["stages"][1]["b0"]["mix/q_a"]
    assert q["A"] == P(None, None, None)
