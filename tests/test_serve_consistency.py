"""Serving-path correctness: prefill + step-by-step decode must reproduce
the full-sequence forward logits (the strongest cache invariant).

Covers the cache families: full-KV GQA, ring-buffer SWA, local/global
alternation + softcaps (gemma2), latent MLA (naive and absorbed), SSM
state recurrence (mamba2), hybrid+MoE (jamba), cross-attention (whisper),
and the VLM patch prefix (phi-3-vision).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import make_model

jax.config.update("jax_platform_name", "cpu")

PREFILL, DECODE = 24, 8
ARCH_SAMPLE = [
    "h2o-danube-3-4b",      # SWA ring cache
    "gemma2-9b",            # local/global + softcap + post-norms
    "deepseek-v3-671b",     # MLA latent cache (+MoE)
    "mamba2-1.3b",          # SSM state
    "jamba-1.5-large-398b", # hybrid + MoE
    "whisper-large-v3",     # enc-dec cross attention
    "phi-3-vision-4.2b",    # patch prefix
    "chatglm3-6b",          # rope half + kv=2
]


def _setup(name, **model_kw):
    cfg = get_config(name).reduced()
    model = make_model(cfg, remat=False, **model_kw)
    params = model.init(jax.random.PRNGKey(0))
    adapters = model.init_adapters(jax.random.PRNGKey(1), rank=4)
    rng = np.random.default_rng(3)
    total = PREFILL + DECODE
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, total)), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(2, cfg.encoder_seq, cfg.frontend_dim)),
            jnp.float32)
    if cfg.frontend == "vision_patches":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(2, cfg.n_prefix_tokens, cfg.frontend_dim)),
            jnp.float32)
    return cfg, model, params, adapters, batch


@pytest.mark.parametrize("name", ARCH_SAMPLE)
def test_decode_matches_full_forward(name):
    cfg, model, params, adapters, batch = _setup(name)
    total = PREFILL + DECODE
    n_prefix = cfg.n_prefix_tokens if cfg.frontend == "vision_patches" else 0

    full_logits, _ = model.forward(params, adapters, batch, mode="full")
    assert np.isfinite(np.asarray(full_logits, np.float32)).all()

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :PREFILL]
    last, caches = model.prefill(params, adapters, pre_batch,
                                 capacity=total + n_prefix)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full_logits[:, PREFILL - 1], np.float32),
        rtol=2e-2, atol=2e-2, err_msg=f"{name}: prefill logits diverge")

    for t in range(PREFILL, total):
        pos = jnp.asarray(t + n_prefix, jnp.int32)
        logits, caches = model.decode_step(params, adapters, caches,
                                           batch["tokens"][:, t], pos)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{name}: decode diverges at t={t}")


def test_mla_absorbed_matches_naive():
    cfg, model, params, adapters, batch = _setup("deepseek-v3-671b")
    model_abs = make_model(cfg, remat=False, mla_absorbed=True)
    total = PREFILL + DECODE
    caches = model.init_cache(2, total)
    caches2 = model.init_cache(2, total)
    for t in range(total):
        tok = batch["tokens"][:, t]
        pos = jnp.asarray(t, jnp.int32)
        logits_naive, caches = model.decode_step(params, adapters, caches,
                                                 tok, pos)
        logits_abs, caches2 = model_abs.decode_step(params, adapters,
                                                    caches2, tok, pos)
    np.testing.assert_allclose(np.asarray(logits_abs, np.float32),
                               np.asarray(logits_naive, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_swa_ring_wraps_correctly():
    """With window < context, ring-buffer decode must still match full
    forward (the window mask hides everything the ring evicted)."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    # shrink the window so it wraps inside the test horizon
    from dataclasses import replace
    from repro.configs.base import BlockSpec, Stage
    stages = tuple(Stage(unit=tuple(
        BlockSpec(kind=b.kind, ffn=b.ffn, window=8) for b in s.unit),
        repeat=s.repeat) for s in cfg.stages)
    cfg = replace(cfg, stages=stages)
    model = make_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    total = 32
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (1, total)), jnp.int32)}
    full_logits, _ = model.forward(params, None, batch, mode="full")

    pre = {"tokens": batch["tokens"][:, :16]}
    _, caches = model.prefill(params, None, pre, capacity=total)
    for t in range(16, total):
        logits, caches = model.decode_step(params, None, caches,
                                           batch["tokens"][:, t],
                                           jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=3e-2, atol=3e-2,
            err_msg=f"ring decode diverges at t={t}")
