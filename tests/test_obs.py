"""The `repro.obs` subsystem: registry semantics, exporters, spans,
zero-retrace guarantees, and the `ServiceHealth` acceptance snapshot.

What is pinned here (see ``docs/observability.md``):

* histogram bucket-edge (`le`) semantics and percentile reads,
* the Prometheus text export round-trips through its own parser,
* `snapshot()` stays consistent under concurrent writers,
* enabling/disabling metrics never retraces a warm plan or serving
  executable (the zero-retrace guarantee the CI bench smoke also gates),
* the deprecation shims (`dispatch_counter`, `plan_stats`,
  `trace_counts`) keep their pre-registry behavior,
* a 128-client mixed-codec async run yields a `ServiceHealth.snapshot()`
  with every section populated (the PR acceptance criterion).
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.core.strategy import ClientUpdate, ServerState, get_strategy
from repro.fl import AsyncAggregator
from repro.fl.async_agg import REJECT_REASONS
from repro.lora import init_adapters
from repro.obs import (MetricsRegistry, ServiceHealth, get_registry,
                       parse_prometheus, set_enabled, span, to_prometheus,
                       write_jsonl_snapshot)

from _cohorts import R_MAX, SPECS, hetero_cohort, mixed_codec_cohort


# ------------------------------------------------------- registry model ----
def test_histogram_bucket_edge_semantics():
    """Prometheus `le` semantics: a value v lands in the first bucket
    whose upper edge e satisfies v <= e; above the last edge it lands in
    the overflow bucket."""
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0,          # both <= 1.0 -> bucket 0
              1.0001, 2.0,       # bucket 1
              4.0,               # exactly the last edge -> bucket 2
              4.0001, 100.0):    # overflow
        h.observe(v)
    sample = h.samples()[""]
    assert sample["buckets"] == [[1.0, 2], [2.0, 2], [4.0, 1]]
    assert sample["overflow"] == 2
    assert sample["count"] == 7
    assert sample["max"] == 100.0
    assert np.isclose(sample["sum"], 0.5 + 1.0 + 1.0001 + 2.0 + 4.0
                      + 4.0001 + 100.0)
    # percentile reads the bucket upper edge; overflow reports the max
    assert h.percentile(0.0) == 1.0
    assert h.percentile(0.5) == 2.0
    assert h.percentile(1.0) == 100.0
    assert reg.histogram("empty", buckets=(1.0,)).percentile(0.5) is None


def test_histogram_rejects_bad_buckets():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="increasing"):
        reg.histogram("a", buckets=(1.0, 1.0))
    with pytest.raises(ValueError, match="finite"):
        reg.histogram("b", buckets=(1.0, float("inf")))
    with pytest.raises(ValueError, match="at least one"):
        reg.histogram("c", buckets=())


def test_counter_monotone_and_label_model():
    reg = MetricsRegistry()
    c = reg.counter("evts_total", labelnames=("reason",))
    c.labels(reason="x").inc()
    c.labels(reason="x").inc(2)
    c.labels(reason="y").inc()
    assert c.samples() == {"reason=x": 3.0, "reason=y": 1.0}
    with pytest.raises(ValueError, match="labels"):
        c.inc()                       # labelled family needs .labels()
    with pytest.raises(ValueError, match="monotone"):
        c.labels(reason="x").inc(-1)
    with pytest.raises(ValueError, match="missing label"):
        c.labels(nope="x")
    # re-registration returns the same instrument; conflicts raise
    assert reg.counter("evts_total", labelnames=("reason",)) is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("evts_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("evts_total", labelnames=("other",))


def test_disabled_registry_is_a_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c_total")
    h = reg.histogram("h", buckets=(1.0,))
    g = reg.gauge("g")
    c.inc(5)
    h.observe(0.5)
    g.set(3.0)
    assert c.value == 0.0 and h.count == 0 and g.value == 0.0


def test_scoped_window_saves_and_restores():
    reg = MetricsRegistry()
    c = reg.counter("c_total")
    c.inc(7)
    with reg.scoped():
        assert c.value == 0.0         # zeroed inside the window
        c.inc(2)
        assert c.value == 2.0
    assert c.value == 7.0             # restored, window discarded
    reg.reset()
    assert c.value == 0.0             # cached handle survives reset


def test_snapshot_consistent_under_concurrent_writers():
    """`snapshot()` while worker threads fold into the same registry:
    no exceptions, monotone counter reads, and exact final totals."""
    reg = MetricsRegistry()
    c = reg.counter("folds_total")
    h = reg.histogram("lat", buckets=(1.0, 2.0))
    n_threads, n_iters = 4, 1000
    start = threading.Barrier(n_threads + 1)

    def fold():
        start.wait()
        for i in range(n_iters):
            c.inc()
            h.observe(float(i % 3))

    workers = [threading.Thread(target=fold) for _ in range(n_threads)]
    for w in workers:
        w.start()
    start.wait()
    seen = 0.0
    for _ in range(50):
        snap = reg.snapshot()
        v = snap["counters"]["folds_total"][""]
        assert v >= seen, "counter went backwards across snapshots"
        seen = v
        hs = snap["histograms"]["lat"][""]
        # each child is read under its family lock: internally consistent
        assert sum(n for _, n in hs["buckets"]) + hs["overflow"] \
            == hs["count"]
    for w in workers:
        w.join()
    assert c.value == n_threads * n_iters
    assert h.count == n_threads * n_iters


# ------------------------------------------------------------- exporters ----
def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(41)
    reg.counter("rej_total", labelnames=("reason",)) \
        .labels(reason="nan_tensor").inc(3)
    reg.gauge("depth").set(7)
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.5):
        h.observe(v)
    return reg


def test_prometheus_export_round_trips():
    reg = _populated_registry()
    text = to_prometheus(reg)
    assert "# TYPE req_total counter" in text
    assert "# TYPE lat_seconds histogram" in text
    parsed = parse_prometheus(text)
    assert parsed["req_total"][frozenset()] == 41.0
    assert parsed["rej_total"][frozenset({("reason", "nan_tensor")})] == 3.0
    assert parsed["depth"][frozenset()] == 7.0
    # histogram series expand cumulatively, with the implicit +Inf bucket
    b = parsed["lat_seconds_bucket"]
    assert b[frozenset({("le", "0.1")})] == 1.0
    assert b[frozenset({("le", "1")})] == 2.0
    assert b[frozenset({("le", "+Inf")})] == 3.0
    assert parsed["lat_seconds_count"][frozenset()] == 3.0
    assert np.isclose(parsed["lat_seconds_sum"][frozenset()], 3.05)


def test_jsonl_snapshot_appends_parseable_records(tmp_path):
    reg = _populated_registry()
    path = tmp_path / "metrics.jsonl"
    write_jsonl_snapshot(path, reg, phase="warm")
    reg.counter("req_total").inc()
    write_jsonl_snapshot(path, reg, phase="steady")
    records = [json.loads(line)
               for line in path.read_text().splitlines()]
    assert [r["phase"] for r in records] == ["warm", "steady"]
    assert records[0]["metrics"]["counters"]["req_total"][""] == 41.0
    assert records[1]["metrics"]["counters"]["req_total"][""] == 42.0


# ----------------------------------------------------------------- spans ----
def test_span_times_into_stage_histogram():
    reg = MetricsRegistry()
    with span("fold", registry=reg) as sp:
        sp.block(jnp.ones((4,)) * 2)
    hist = reg.get("obs_span_seconds")
    assert hist._children[("fold",)].count == 1
    assert sp.duration_s is not None and sp.duration_s >= 0.0


def test_span_is_inert_under_jit_tracing():
    """A span opened while jax is tracing must be a no-op: nothing
    observed, no Python timestamps baked into the jaxpr."""
    reg = MetricsRegistry()

    @jax.jit
    def f(x):
        with span("fold", registry=reg):
            return x * 2

    np.testing.assert_array_equal(np.asarray(f(jnp.ones((3,)))),
                                  np.full((3,), 2.0))
    hist = reg.get("obs_span_seconds")
    assert hist is None or ("fold",) not in hist._children


# ---------------------------------------------------------- zero-retrace ----
def _warm_cohort(n=4, seed=11):
    adapters, ranks, w = hetero_cohort(n, seed=seed)
    return adapters, ranks, w


def test_metrics_toggle_never_retraces_warm_plan_path():
    from repro.kernels.runtime import trace_counts
    adapters, ranks, w = _warm_cohort()
    s = get_strategy("rbla").with_options()
    run = lambda: s.aggregate_adapters(adapters, w, r_max=R_MAX,
                                       client_ranks=ranks, backend="ref")
    jax.block_until_ready(jax.tree.leaves(run()))        # warm
    execs = len(s.__dict__.get("_plan_exec_cache", {}))
    traces = dict(trace_counts)
    prev = set_enabled(True)
    try:
        for enabled in (True, False, True):
            set_enabled(enabled)
            jax.block_until_ready(jax.tree.leaves(run()))
    finally:
        set_enabled(prev)
    assert len(s.__dict__.get("_plan_exec_cache", {})) == execs
    assert dict(trace_counts) == traces


def test_metrics_toggle_never_retraces_warm_serving_path():
    from repro.kernels.runtime import trace_counts
    from repro.serving import AdapterStore, ServingEngine
    rng = np.random.default_rng(0)
    specs = {"proj": (16, 16)}
    store = AdapterStore(specs, r_max=4)
    engine = ServingEngine(
        {"proj": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)},
        store)
    for t in range(4):
        store.register(f"t{t}", rank=1 + t % 4)
    engine.publish(init_adapters(jax.random.PRNGKey(0), specs, 4, 4))
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    ids = jnp.asarray(rng.integers(1, 5, 8), jnp.int32)
    jax.block_until_ready(engine.apply("proj", x, ids))   # warm
    traces = trace_counts.get("batched_lora_matmul", 0)
    prev = set_enabled(True)
    try:
        for enabled in (True, False, True):
            set_enabled(enabled)
            mix = jnp.asarray(rng.integers(1, 5, 8), jnp.int32)
            jax.block_until_ready(engine.apply("proj", x, mix))
    finally:
        set_enabled(prev)
    assert trace_counts.get("batched_lora_matmul", 0) == traces


# ------------------------------------------------------ deprecation shims ----
def test_dispatch_counter_shim_still_windows():
    from repro.core.plan import dispatch_counter
    from repro.kernels.runtime import count_dispatch
    dispatch_counter.reset()
    count_dispatch(kernel="shim_probe")
    count_dispatch(n=2, kernel="shim_probe")
    assert dispatch_counter.reset() == 3          # windowed read-and-zero
    assert dispatch_counter.count == 0
    # the cumulative registry series kept counting across the reset
    total = get_registry().get("kernel_dispatches_total")
    assert total.samples()["entry=shim_probe"] >= 3.0


def test_plan_stats_shim_mirrors_into_registry():
    adapters, ranks, w = _warm_cohort(seed=12)
    s = get_strategy("zeropad").with_options()
    for _ in range(3):
        s.aggregate_adapters(adapters, w, r_max=R_MAX,
                             client_ranks=ranks, backend="ref")
    stats = s.__dict__["plan_stats"]              # the public shim dict
    assert stats["misses"] == 1 and stats["hits"] == 2
    hits = get_registry().get("plan_cache_hits_total")
    assert hits.samples().get("strategy=zeropad", 0) >= 2.0


# ---------------------------------------------- per-reason rejection view ----
def test_service_health_rejections_match_reason_catalog():
    s = get_strategy("rbla")
    state = ServerState(
        adapters=init_adapters(jax.random.PRNGKey(1), SPECS, R_MAX, R_MAX),
        base_trainable={}, r_max=R_MAX)
    agg = AsyncAggregator(s, state, registry=MetricsRegistry())
    health = ServiceHealth(aggregator=agg)
    assert health.rejections() == {}
    adapters, ranks, w = _warm_cohort(2, seed=5)
    good = ClientUpdate(adapters=adapters[0], base_trainable={},
                        n_examples=2.0, rank=int(ranks[0]))
    with pytest.raises(ValueError):
        agg.submit(ClientUpdate(adapters=adapters[0], base_trainable={},
                                n_examples=-1.0, rank=int(ranks[0])))
    assert health.rejections() == {"bad_mass": 1.0}
    agg.submit(good)
    assert health.rejections() == {"bad_mass": 1.0}   # accepts don't count
    assert set(health.rejections()) <= set(REJECT_REASONS)


# ------------------------------------------- the acceptance-criterion run ----
def test_service_health_snapshot_128_client_mixed_codec_run():
    """The PR acceptance criterion: a 128-client mixed-codec async run
    (buffered mini-cohorts, publishes into a live serving store) yields
    a `ServiceHealth.snapshot()` where every section is populated."""
    from repro.serving import AdapterStore, ServingEngine
    n = 128
    encoded, _, ranks, w, codecs = mixed_codec_cohort(n, seed=2)
    rng = np.random.default_rng(3)
    store = AdapterStore(SPECS, r_max=R_MAX, init_pages=8,
                         init_tenant_capacity=8)
    weights = {p: jnp.asarray(rng.normal(size=(fi, fo)), jnp.float32)
               for p, (fo, fi) in SPECS.items()}
    engine = ServingEngine(weights, store)
    for t in range(4):
        store.register(f"tenant-{t}", rank=1 + t % R_MAX)

    # with_options copy: plan_stats on the shared registered instance
    # accumulates across the whole test process
    s = get_strategy("rbla").with_options()
    state = ServerState(
        adapters=init_adapters(jax.random.PRNGKey(9), SPECS, R_MAX, R_MAX),
        base_trainable={}, r_max=R_MAX)
    agg = AsyncAggregator(s, state, buffer_size=16, backend="ref",
                          on_publish=engine.publisher(),
                          registry=MetricsRegistry())
    for i in range(n):
        agg.submit(ClientUpdate(adapters=encoded[i], base_trainable={},
                                n_examples=float(w[i]), rank=int(ranks[i])),
                   model_version=max(agg.version - i % 5, 0))
    x = jnp.asarray(rng.normal(size=(8, SPECS["fc1"][1])), jnp.float32)
    jax.block_until_ready(
        engine.apply("fc1", x, jnp.asarray([1, 2, 3, 4, 0, 1, 2, 3],
                                           jnp.int32)))

    health = ServiceHealth(aggregator=agg, engine=engine)
    snap = health.snapshot()

    svc = snap["service"]
    assert svc["n_received"] == n and svc["n_dropped"] == 0
    assert svc["version"] == n // 16 and svc["buffer_depth"] == 0
    assert svc["wire_bytes_received"] > 0

    assert snap["codec_mix"] == {
        c: float(sum(1 for cc in codecs if cc == c))
        for c in ("int8", "bf16", "none")}
    assert snap["rejections"] == {}

    stale = snap["staleness"]
    assert stale["count"] == n and stale["p99"] is not None

    lat = snap["latency"]
    for stage in ("submit", "flush", "fold"):
        assert lat[stage] is not None and lat[stage]["count"] > 0, stage
    assert lat["publish"] is not None            # on_publish wired in
    for view in (lat["submit"], lat["fold"]):
        assert view["p50"] <= view["p99"]

    pc = snap["plan_cache"]
    # every mini-cohort here has a distinct rank multiset, so each of
    # the 8 flushes compiles its own plan -- what matters is that the
    # section reports live numbers, not a particular hit rate
    assert pc["hits"] + pc["misses"] == svc["n_flushes"]
    assert pc["hit_rate"] is not None

    st = snap["store"]
    assert st["version"] > 0 and st["n_tenants"] == 4
    assert st["pinned_snapshots"] == 0
    occ = st["page_occupancy"]
    assert occ and all({"pages", "pages_used", "page_rows"} <= set(v)
                       for v in occ.values())
    json.dumps(snap)                             # plain-JSON payload
